"""Checkpoint subsystem: save/restore round-trips FLState exactly —
including the PR-3 async buffer slot (FLState.buffer) and the
compression subsystem's EF21 state (FLState.ef) — and a --resume
continues training bit-identically to an uninterrupted run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.core import (get_client_opt, get_server_opt, init_fl_state,
                        make_fl_round, make_loss)


def _assert_trees_equal(a_tree, b_tree):
    for a, b in zip(jax.tree_util.tree_leaves(a_tree),
                    jax.tree_util.tree_leaves(b_tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_roundtrip_flstate(tmp_path, rng):
    params = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
              "b": {"x": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)}}
    sopt = get_server_opt("fedadam")
    state = init_fl_state(params, sopt)
    assert state.buffer is None and state.ef is None
    save(str(tmp_path), state, step=7)
    restored, step = restore(str(tmp_path), like=state)
    assert step == 7
    assert restored.buffer is None and restored.ef is None
    _assert_trees_equal(state, restored)


def test_keep_and_latest(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), tree, step=s, keep=2)
    assert latest_step(str(tmp_path)) == 5
    # only last 2 kept
    _, s = restore(str(tmp_path), like=tree)
    assert s == 5
    with pytest.raises(Exception):
        restore(str(tmp_path), like=tree, step=1)


def test_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), {"w": jnp.zeros((3,))}, step=0)
    with pytest.raises(ValueError):
        restore(str(tmp_path), like={"w": jnp.zeros((4,))})


# ------------------------------------------------- FLState.buffer / .ef
def _async_run(rng, rounds, tmp_path=None, resume_after=None,
               buffer_size=8):
    """Flat async+EF quad run; optionally checkpoint after round
    ``resume_after`` and restore into a FRESH state before continuing —
    must be bit-identical to the uninterrupted run."""
    from repro.compression import CompressionSpec
    from repro.federation import get_scenario
    D, C, K = 40, 4, 2

    def quad(params, batch):
        r = batch["A"] @ params["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    batches = {"A": jnp.asarray(rng.normal(size=(C, K, 8, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(C, K, 8)), jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    scn = get_scenario("zipf_async", buffer_size=buffer_size)
    spec = CompressionSpec(kind="int8", error_feedback=True)
    sopt = get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(make_loss(quad), get_client_opt("delta_sgd"),
                                sopt, num_rounds=10, flat="xla",
                                scenario=scn, compression=spec))
    st = init_fl_state(params, sopt, scn, compression=spec, cohort=C)
    for t in range(rounds):
        st, _, _ = rnd(st, batches)
        if resume_after is not None and t == resume_after:
            save(str(tmp_path), st, step=t)
            fresh = init_fl_state(params, sopt, scn, compression=spec,
                                  cohort=C)
            st, step = restore(str(tmp_path), like=fresh)
            assert step == t
    return st


def test_roundtrip_flstate_with_buffer_and_ef(tmp_path, rng):
    """Satellite acceptance: FLState with an ALLOCATED async buffer and
    EF21 error-feedback state (both non-zero after real rounds)
    round-trips exactly, dtypes included."""
    # M=9 > 2 rounds × 4 clients: the buffer is PART-FULL at save time
    state = _async_run(rng, rounds=2, buffer_size=9)
    assert int(state.buffer.count) == 8
    assert float(jnp.max(jnp.abs(state.buffer.delta["x"]))) > 0.0
    assert float(jnp.max(jnp.abs(state.ef["x"]))) > 0.0
    assert state.ef["x"].dtype == jnp.float32
    save(str(tmp_path), state, step=2)
    restored, step = restore(str(tmp_path), like=state)
    assert step == 2
    _assert_trees_equal(state, restored)
    # the template's STRUCTURE gates restore: a buffer-less template
    # must be rejected, not silently mis-mapped
    from repro.core import get_server_opt as _gso
    plain = init_fl_state({"x": jnp.zeros((40,), jnp.float32)},
                          _gso("fedavg"))
    with pytest.raises(ValueError):
        restore(str(tmp_path), like=plain)


def test_resume_parity_with_buffer_and_ef(tmp_path, rng):
    """Save after round 1, restore into a fresh state, continue — equals
    the uninterrupted run bit for bit (round counter, part-full buffer,
    EF tree and params all carried by the checkpoint)."""
    rng2 = np.random.default_rng(0)
    straight = _async_run(rng, rounds=4)
    resumed = _async_run(rng2, rounds=4, tmp_path=tmp_path, resume_after=1)
    assert int(straight.round) == int(resumed.round) == 4
    _assert_trees_equal(straight, resumed)


def test_final_round_always_checkpointed(tmp_path):
    """Satellite acceptance (launch/train._maybe_ckpt): with
    T % ckpt_every != 0 the last round must still be saved, and saves
    are keyed on state.round so post-resume checkpoints sort ABOVE the
    pre-resume ones (keep-newest GC must not eat them)."""
    import argparse

    from repro.core.fed_round import FLState
    from repro.launch.train import _maybe_ckpt

    def st(completed_rounds):
        return FLState({"w": jnp.zeros((2,))}, {},
                       jnp.asarray(completed_rounds, jnp.int32))

    args = argparse.Namespace(ckpt_dir=str(tmp_path), ckpt_every=20)
    T = 7                                     # t % 20 != 0 for t in 1..6
    for t in range(T):
        _maybe_ckpt(args, st(t + 1), t, final=(t == T - 1))
    assert latest_step(str(tmp_path)) == T
    # resumed run: loop restarts at t=0 but round continues at T — the
    # new checkpoints must be numbered past the pre-resume ones
    _maybe_ckpt(args, st(T + 1), 0)
    assert latest_step(str(tmp_path)) == T + 1


def test_roundtrip_flat_form_state(tmp_path, rng):
    """Flat-form FLState round-trip (repro.core.fed_loop.FlatFLState —
    what a fused run carries between block boundaries): save/restore is
    bit-exact on the packed buffers, and unflattening the restored flat
    state equals the pytree state it was packed from — so a fused run's
    block-boundary checkpoints interoperate with the host loop's."""
    from repro.compression import CompressionSpec
    from repro.core import flat as fp
    from repro.core import flatten_fl_state, unflatten_fl_state
    from repro.federation import get_scenario
    params = {"w": jnp.asarray(rng.normal(size=(40, 3)), jnp.float32),
              "e": jnp.asarray(rng.normal(size=(9,)), jnp.bfloat16)}
    scn = get_scenario("zipf_async")
    comp = CompressionSpec(kind="int8", error_feedback=True)
    sopt = get_server_opt("fedadam")
    state = init_fl_state(params, sopt, scn, compression=comp, cohort=3)
    state = state._replace(ef=jax.tree.map(lambda e: e + 0.5, state.ef))
    layout = fp.layout_of(params)
    fstate = flatten_fl_state(state, layout)
    save(str(tmp_path), fstate, step=4)
    restored, step = restore(str(tmp_path), like=fstate)
    assert step == 4
    _assert_trees_equal(fstate, restored)
    _assert_trees_equal(jax.tree_util.tree_leaves(
        unflatten_fl_state(restored, layout)),
        jax.tree_util.tree_leaves(state))


# ------------------------------------------------ driver crash-resume
def _lm_args(**over):
    import argparse
    base = dict(arch="tinyllama-1.1b", reduced=True, layers=1, d_model=64,
                rounds=6, clients_per_round=2, num_clients=10, alpha=0.1,
                local_steps=2, batch=2, seq=16, client_opt="delta_sgd",
                server_opt="fedavg", scenario=None, out=None,
                compression="none", k_frac=0.25, error_feedback=False,
                robust_agg="mean", quorum=0, lr=0.05, fedprox_mu=0.0,
                use_pallas=False, rounds_per_call=1, flat=False,
                ckpt_dir=None, ckpt_every=2, resume=False, seed=0)
    base.update(over)
    return argparse.Namespace(**base)


@pytest.mark.slow
def test_train_lm_crash_resume_bit_exact(tmp_path):
    """Satellite acceptance (crash-resume hardening): kill an async+EF
    LM run mid-way, --resume from the last checkpoint, and the final
    state — params, server state, round counter, async buffer (count
    included) and EF21 tree — is bit-identical to the uninterrupted
    run. Works because (a) every state slot rides the checkpoint and
    (b) the synthetic-data rng is derived per round from (seed, round),
    so the resumed run replays the exact batch stream."""
    from repro.launch.train import train_lm
    kw = dict(scenario="zipf_async", compression="int8",
              error_feedback=True)
    straight = train_lm(_lm_args(ckpt_dir=str(tmp_path / "ref"), **kw))
    # "crash" after 3 of 6 rounds, then resume for the remaining 3
    crash_dir = str(tmp_path / "crash")
    train_lm(_lm_args(rounds=3, ckpt_dir=crash_dir, **kw))
    resumed = train_lm(_lm_args(rounds=3, ckpt_dir=crash_dir,
                                resume=True, **kw))
    assert int(straight.round) == int(resumed.round) == 6
    assert int(resumed.buffer.count) == int(straight.buffer.count)
    _assert_trees_equal(straight, resumed)


@pytest.mark.slow
def test_train_lm_crash_resume_fused_blocks(tmp_path):
    """Same contract through the round-fused driver path: checkpoints
    land on block boundaries, and a resume from one reproduces the
    uninterrupted fused run bit for bit."""
    from repro.launch.train import train_lm
    kw = dict(rounds_per_call=3, flat=True)
    straight = train_lm(_lm_args(ckpt_dir=str(tmp_path / "ref"), **kw))
    crash_dir = str(tmp_path / "crash")
    train_lm(_lm_args(rounds=3, ckpt_dir=crash_dir, **kw))
    resumed = train_lm(_lm_args(rounds=3, ckpt_dir=crash_dir,
                                resume=True, **kw))
    assert int(straight.round) == int(resumed.round) == 6
    _assert_trees_equal(straight, resumed)


def test_fused_block_checkpoint_resumes_host_loop(tmp_path, rng):
    """A checkpoint written at a fused block boundary resumes a HOST
    loop bit-identically: fused rounds 0..3 -> checkpoint -> host rounds
    4..5 equals six host rounds straight through."""
    from repro.core import (flatten_fl_state, make_fl_loop,
                            unflatten_fl_state)

    def quad(p, batch):
        r = batch["A"] @ p["x"] - batch["b"]
        return 0.5 * jnp.mean(r * r), {}

    D, C, K, Rb = 24, 3, 2, 4
    batches = {"A": jnp.asarray(rng.normal(size=(6, C, K, 4, D)),
                                jnp.float32),
               "b": jnp.asarray(rng.normal(size=(6, C, K, 4)),
                                jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32)}
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                flat="xla"))

    st_ref = init_fl_state(params, sopt)
    for r in range(6):
        st_ref, _, _ = rnd(st_ref, jax.tree.map(lambda x: x[r], batches))

    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=Rb, flat="xla")
    fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
    fst, _ = jax.jit(loop)(fst, jax.tree.map(lambda x: x[:Rb], batches))
    boundary = unflatten_fl_state(fst, loop.layout)
    save(str(tmp_path), boundary, step=int(boundary.round))

    st, step = restore(str(tmp_path), like=init_fl_state(params, sopt))
    assert step == Rb and int(st.round) == Rb
    for r in range(Rb, 6):
        st, _, _ = rnd(st, jax.tree.map(lambda x: x[r], batches))
    _assert_trees_equal(st_ref.params, st.params)
