"""Checkpoint subsystem: save/restore round-trips FLState exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.core import get_server_opt, init_fl_state


def test_roundtrip_flstate(tmp_path, rng):
    params = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
              "b": {"x": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)}}
    sopt = get_server_opt("fedadam")
    state = init_fl_state(params, sopt)
    save(str(tmp_path), state, step=7)
    restored, step = restore(str(tmp_path), like=state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_keep_and_latest(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), tree, step=s, keep=2)
    assert latest_step(str(tmp_path)) == 5
    # only last 2 kept
    _, s = restore(str(tmp_path), like=tree)
    assert s == 5
    with pytest.raises(Exception):
        restore(str(tmp_path), like=tree, step=1)


def test_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), {"w": jnp.zeros((3,))}, step=0)
    with pytest.raises(ValueError):
        restore(str(tmp_path), like={"w": jnp.zeros((4,))})
