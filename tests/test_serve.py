"""Serving driver smoke (launch/serve.py + examples/serve_decode.py):
the decode server loads a TRAINING checkpoint — an FLState whose
manifest keys carry the ``params/`` prefix — through
``repro.checkpoint.restore_params`` and answers one greedy-decode
request, deterministically."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_params, save
from repro.configs import get_config
from repro.core.fed_round import FLState
from repro.launch.serve import build_parser, run
from repro.models import build_model

ARCH = "tinyllama-1.1b"
_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _args(*extra):
    return build_parser().parse_args(
        ["--arch", ARCH, "--reduced", "--batch", "2",
         "--prompt-len", "16", "--gen", "8", *extra])


def test_serve_run_fresh_init():
    out = run(_args())
    assert out["tokens"].shape == (2, 8)
    assert out["tokens"].dtype == np.int32
    assert out["tok_per_s"] > 0
    assert out["ckpt_step"] is None


@pytest.mark.slow
def test_serve_run_loads_training_checkpoint(tmp_path):
    """An FLState checkpoint (params under the 'params/' manifest
    prefix) loads into the serving template; the loaded params actually
    drive the decode (different checkpoint -> different tokens) and the
    request is reproducible."""
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg, jnp.float32)
    ckpt_params = model.init(jax.random.key(1))    # != serve's seed-0 init
    save(str(tmp_path), FLState(ckpt_params, {},
                                jnp.asarray(3, jnp.int32)), step=3)

    fresh = run(_args())
    loaded = run(_args("--ckpt-dir", str(tmp_path)))
    assert loaded["ckpt_step"] == 3
    assert loaded["tokens"].shape == (2, 8)
    assert not np.array_equal(loaded["tokens"], fresh["tokens"])
    again = run(_args("--ckpt-dir", str(tmp_path), "--ckpt-step", "3"))
    np.testing.assert_array_equal(again["tokens"], loaded["tokens"])


def test_restore_params_key_mapping(tmp_path):
    """restore_params matches manifest keys both bare (a params-only
    checkpoint) and under the 'params/' prefix (FLState), and rejects a
    checkpoint missing a template leaf."""
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": {"x": jnp.ones((4,), jnp.bfloat16)}}
    save(str(tmp_path / "bare"), params, step=5)
    got, step = restore_params(str(tmp_path / "bare"), params)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype

    save(str(tmp_path / "fl"),
         FLState(params, {"m": jnp.zeros((2,))}, jnp.asarray(7, jnp.int32)),
         step=7)
    got, step = restore_params(str(tmp_path / "fl"), params)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(params["w"]))

    with pytest.raises(KeyError):
        restore_params(str(tmp_path / "bare"),
                       {**params, "extra": jnp.zeros((2,))})


def test_serve_window_smaller_than_request_errors():
    """Regression: a --window smaller than prompt+gen used to silently
    clamp cache_len and truncate attention context. It must now refuse
    loudly — and serve via a rolling ring buffer when the caller opts
    in with --roll-cache."""
    with pytest.raises(SystemExit, match="smaller than the full"):
        run(_args("--window", "20"))
    rolled = run(_args("--window", "20", "--roll-cache"))
    assert rolled["tokens"].shape == (2, 8)
    # a window that covers the request needs no opt-in and matches the
    # unwindowed decode (nothing ever rolls out of a covering window)
    full = run(_args("--window", "24"))
    np.testing.assert_array_equal(full["tokens"], run(_args())["tokens"])


@pytest.mark.slow
def test_serve_decode_example_subprocess(tmp_path):
    """examples/serve_decode.py end to end: loads a checkpoint via
    --ckpt-dir and prints a generated row."""
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg, jnp.float32)
    save(str(tmp_path), FLState(model.init(jax.random.key(1)), {},
                                jnp.asarray(2, jnp.int32)), step=2)
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(_ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", "serve_decode.py"),
         "--arch", ARCH, "--batch", "1", "--prompt-len", "12",
         "--gen", "4", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "loaded params from" in proc.stdout
    assert "first row:" in proc.stdout
