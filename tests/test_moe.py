"""MoE layer: routing math, capacity behaviour, dense equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as moe
from repro.configs import get_config
from repro.models.moe import apply_moe, init_moe


@pytest.fixture
def cfg():
    return get_config("olmoe-1b-7b").reduced()  # 4 experts, top-2


def _dense_reference(params, x, cfg):
    """Per-token loop: route, run chosen experts densely, combine."""
    B, S, D = x.shape
    xt = np.asarray(x.reshape(-1, D), np.float64)
    logits = xt @ np.asarray(params["router"], np.float64)
    p = np.exp(logits - logits.max(1, keepdims=True))
    p /= p.sum(1, keepdims=True)
    K = cfg.num_experts_per_tok
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        idx = np.argsort(-p[t])[:K]
        w = p[t, idx] / p[t, idx].sum()
        for j, e in enumerate(idx):
            g = xt[t] @ np.asarray(params["w_gate"][e], np.float64)
            u = xt[t] @ np.asarray(params["w_in"][e], np.float64)
            h = (g / (1 + np.exp(-g))) * u
            out[t] += w[j] * (h @ np.asarray(params["w_out"][e], np.float64))
    if "shared" in params:
        sp = params["shared"]
        g = xt @ np.asarray(sp["w_gate"], np.float64)
        u = xt @ np.asarray(sp["w_in"], np.float64)
        out += ((g / (1 + np.exp(-g))) * u) @ np.asarray(sp["w_out"],
                                                         np.float64)
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference(cfg, rng, monkeypatch):
    monkeypatch.setattr(moe, "CAPACITY_FACTOR", 8.0)  # no drops
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)) * 0.5, jnp.float32)
    out, aux = apply_moe(params, x, cfg)
    ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_gate_weights_normalized_and_aux_positive(cfg, rng):
    params = init_moe(jax.random.key(1), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    out, aux = apply_moe(params, x, cfg)
    assert np.isfinite(float(aux)) and float(aux) > 0
    assert out.shape == x.shape


def test_aux_loss_uniform_router_is_coef(cfg):
    """With a perfectly uniform router, the Switch aux loss equals the
    coefficient exactly (E * (1/E) * (1) ... normalised by K)."""
    params = init_moe(jax.random.key(2), cfg, jnp.float32)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jnp.ones((1, 64, cfg.d_model), jnp.float32)
    _, aux = apply_moe(params, x, cfg)
    # me = 1/E; ce = K/E per expert... sum(me*ce)*E/K = 1 -> aux = coef
    assert float(aux) == pytest.approx(cfg.router_aux_coef, rel=1e-3)


def test_capacity_drops_tokens(cfg, rng, monkeypatch):
    """With capacity factor ~0, all tokens drop -> output reduces to the
    shared-expert path (zero for olmoe which has none)."""
    monkeypatch.setattr(moe, "_capacity", lambda T, E, K: 4)
    params = init_moe(jax.random.key(3), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 256, cfg.d_model)), jnp.float32)
    out, _ = apply_moe(params, x, cfg)
    # most tokens dropped -> mostly zeros (no shared experts in olmoe)
    frac_zero = float(jnp.mean((jnp.abs(out) < 1e-9).astype(jnp.float32)))
    assert frac_zero > 0.5


def test_shared_expert_path(rng, monkeypatch):
    monkeypatch.setattr(moe, "CAPACITY_FACTOR", 8.0)
    cfg = get_config("deepseek-v3-671b").reduced()
    params = init_moe(jax.random.key(4), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)) * 0.3, jnp.float32)
    out, _ = apply_moe(params, x, cfg)
    ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_grads_flow_through_dispatch(cfg, rng):
    params = init_moe(jax.random.key(5), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)), jnp.float32)

    def loss(p):
        out, aux = apply_moe(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    assert float(jnp.abs(g["router"]).sum()) > 0  # router receives gradient
