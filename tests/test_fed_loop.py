"""Round-fused training loop (repro.core.fed_loop): R fused rounds must
be BIT-EXACT vs R host-loop rounds for every flat engine × scenario
combination (sync, stragglers, async, bandwidth-tiered compression),
including the 8-device sharded mesh with both HLO assertions run on the
SCANNED computation; plus the donation contract (carried buffers update
in place, peak live memory independent of R) and the launch-schedule
invariant (the scan body traces the fused kernel pair once — 2·K
launches per block trace, an executed schedule of exactly 2·K·R)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (arena_gather, flatten_fl_state, get_client_opt,
                        get_server_opt, init_fl_state, make_fl_loop,
                        make_fl_round, make_loss, unflatten_fl_state)
from repro.core import flat as fp

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs >= 8 devices "
                                   "(XLA_FLAGS=--xla_force_host_platform"
                                   "_device_count=8)")

R, C, K, D, E = 4, 8, 3, 96, 18


def _problem(rng):
    """Quadratic FL problem, mixed f32/bf16 tree, R stacked rounds."""
    def quad(params, batch):
        x32 = params["x"].astype(jnp.float32)
        e32 = params["e"].astype(jnp.float32)
        r = batch["A"] @ x32 - batch["b"] + jnp.sum(e32) * 0.01
        return 0.5 * jnp.mean(r * r) + 0.05 * jnp.mean(e32 * e32), {}

    batches = {"A": jnp.asarray(rng.normal(size=(R, C, K, 4, D)),
                                jnp.float32),
               "b": jnp.asarray(rng.normal(size=(R, C, K, 4)),
                                jnp.float32)}
    params = {"x": jnp.asarray(rng.normal(size=D), jnp.float32),
              "e": jnp.asarray(rng.normal(size=E), jnp.bfloat16)}
    return quad, params, batches


def _scn(name):
    if name is None:
        return None
    from repro.federation import get_scenario
    return get_scenario(name)


def _comp(scenario_name):
    if scenario_name == "bandwidth_tiered":
        from repro.compression import CompressionSpec
        return CompressionSpec(kind="int8", error_feedback=True)
    return None


def _host_rounds(loss, copt, sopt, params, batches, scn, comp, **kw):
    rnd = jax.jit(make_fl_round(loss, copt, sopt, num_rounds=10,
                                scenario=scn, num_clients=20,
                                compression=comp, **kw))
    st = init_fl_state(params, sopt, scn, compression=comp, cohort=C)
    mets = []
    for r in range(R):
        st, m, _ = rnd(st, jax.tree.map(lambda x: x[r], batches))
        mets.append(m)
    return st, mets


def _assert_states_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("scenario", [None, "dirichlet_stragglers",
                                      "zipf_async", "bandwidth_tiered"])
def test_fused_matches_host_loop_bit_exact(backend, scenario, rng):
    """R fused rounds == R host-loop rounds, bit for bit: final state
    (params, server state, async buffer, EF21 state) AND every round's
    metrics row."""
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn, comp = _scn(scenario), _comp(scenario)
    st, mets = _host_rounds(loss, copt, sopt, params, batches, scn, comp,
                            flat=backend)

    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat=backend,
                        scenario=scn, num_clients=20, compression=comp)
    assert loop.state_form == "flat"
    fst = flatten_fl_state(
        init_fl_state(params, sopt, scn, compression=comp, cohort=C),
        loop.layout)
    fst, fmets = jax.jit(loop, donate_argnums=0)(fst, batches)
    st2 = unflatten_fl_state(fst, loop.layout)

    _assert_states_equal(st, st2)
    assert int(st2.round) == R
    for r in range(R):
        for k in mets[r]:
            np.testing.assert_array_equal(
                np.asarray(mets[r][k], np.float32),
                np.asarray(jax.tree.map(lambda m: m[r], fmets)[k],
                           np.float32), err_msg=f"round {r} metric {k}")


def test_fused_arena_gather_matches_stacked(rng):
    """The device-side arena gather path (stage arena once + ship
    (R, C, K, b) indices) produces the same batches — and therefore the
    same bit-exact trajectory — as pre-stacked batches."""
    quad, params, _ = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    # arena of examples; "batches" are rows gathered per (round, client)
    arena = {"A": jnp.asarray(rng.normal(size=(500, D)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(500,)), jnp.float32)}
    idx = jnp.asarray(rng.integers(0, 500, size=(R, C, K, 4)), jnp.int32)
    stacked = jax.tree.map(lambda a: a[idx], arena)

    loop_s = make_fl_loop(loss, copt, sopt, params_like=params,
                          num_rounds=10, rounds_per_call=R, flat="xla")
    fst = flatten_fl_state(init_fl_state(params, sopt), loop_s.layout)
    fst_s, mets_s = jax.jit(loop_s)(fst, stacked)

    loop_a = make_fl_loop(loss, copt, sopt, params_like=params,
                          num_rounds=10, rounds_per_call=R, flat="xla",
                          gather=arena_gather)
    fst = flatten_fl_state(init_fl_state(params, sopt), loop_a.layout)
    fst_a, mets_a = jax.jit(loop_a, static_argnums=())(fst, idx,
                                                       arena=arena)
    _assert_states_equal(fst_s, fst_a)
    _assert_states_equal(mets_s, mets_a)


def test_fused_requires_flat_engine():
    with pytest.raises(ValueError, match="flat engine"):
        make_fl_loop(lambda p, b, g, pl: (0.0, {}),
                     get_client_opt("delta_sgd"), get_server_opt("fedavg"),
                     params_like={"x": jnp.zeros(4)}, num_rounds=1,
                     flat=False)


def test_fused_state_donated_and_live_buffers_flat_in_R(rng):
    """Donation contract: jit(loop, donate_argnums=0) consumes the
    carried FlatFLState in place — every input buffer is deleted after
    the call, no donation warning fires, and the number of live device
    buffers after a block is the same for R=2 and R=8 (peak live state
    does not grow with R)."""
    import warnings
    quad, params, _ = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")

    def run_block(R_n):
        rng_n = np.random.default_rng(1)
        batches = {
            "A": jnp.asarray(rng_n.normal(size=(R_n, C, K, 4, D)),
                             jnp.float32),
            "b": jnp.asarray(rng_n.normal(size=(R_n, C, K, 4)),
                             jnp.float32)}
        loop = make_fl_loop(loss, copt, sopt, params_like=params,
                            num_rounds=10, rounds_per_call=R_n,
                            flat="xla")
        fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
        donated = [fst.P, fst.round]
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # donation complaints -> fail
            out, mets = jax.jit(loop, donate_argnums=0)(fst, batches)
        jax.block_until_ready(out.P)
        for buf in donated:
            assert buf.is_deleted(), "carried buffer was NOT donated"
        del batches, mets
        live = [a for a in jax.live_arrays()
                if a.size >= params["x"].size]   # state-sized buffers
        return out, len(live)

    out2, live2 = run_block(2)
    n2 = int(out2.round)
    del out2
    out8, live8 = run_block(8)
    assert int(out8.round) == 8 and n2 == 2
    del out8
    # both measurements taken with one live block result in scope:
    # identical state-sized footprint regardless of R
    assert live2 == live8, (live2, live8)


def test_fused_launch_schedule_2K_per_block_trace(rng):
    """The 2-launches-per-local-step invariant under fusion: tracing one
    R-round block costs exactly 2 pallas launches — the double scan
    (R rounds × K local steps) traces the fused kernel pair ONCE, same
    as a single host round, so the EXECUTED schedule of a block is
    exactly 2·K·R launches: the single round's 2·K, scaled by exactly
    ×R, with no extra launches introduced by the fusion."""
    from repro.kernels.delta_sgd import delta_sgd as dk
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    # reference: one host round traces the same 2 launches
    rnd = make_fl_round(loss, copt, sopt, num_rounds=10, flat="pallas")
    st = init_fl_state(params, sopt)
    dk.reset_launch_count()
    st, _, _ = jax.jit(rnd)(st, jax.tree.map(lambda x: x[0], batches))
    jax.block_until_ready(st.params["x"])
    per_round = dk.launch_count()
    assert per_round == 2, dict(dk.LAUNCHES)

    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="pallas")
    fst = flatten_fl_state(init_fl_state(params, sopt), loop.layout)
    dk.reset_launch_count()
    fst, _ = jax.jit(loop)(fst, batches)
    jax.block_until_ready(fst.P)
    assert dk.launch_count() == per_round, dict(dk.LAUNCHES)


def test_flat_state_roundtrip_all_slots(rng):
    """flatten_fl_state/unflatten_fl_state round-trip every FLState slot
    (params, server state, async buffer, EF21 tree) bit-exactly."""
    from repro.compression import CompressionSpec
    quad, params, _ = _problem(rng)
    scn = _scn("zipf_async")
    comp = CompressionSpec(kind="int8", error_feedback=True)
    sopt = get_server_opt("fedadam")
    st = init_fl_state(params, sopt, scn, compression=comp, cohort=C)
    # make the buffer/ef non-trivial so the round-trip proves value
    # preservation, not just zeros
    st = st._replace(
        buffer=st.buffer._replace(delta=jax.tree.map(
            lambda d: d + 0.25, st.buffer.delta)),
        ef=jax.tree.map(lambda e: e - 1.5, st.ef))
    layout = fp.layout_of(params)
    back = unflatten_fl_state(flatten_fl_state(st, layout), layout)
    _assert_states_equal(st, back)


# --------------------------------------------------------- sharded mesh
@needs8
@pytest.mark.slow
@pytest.mark.parametrize("scenario", [None, "dirichlet_stragglers",
                                      "zipf_async"])
def test_sharded_fused_matches_sharded_host(scenario, rng):
    """8-device mesh: the fused scan (tree-form carry, see
    fed_loop.state_form) == the sharded host loop bit-exact, and the
    packed (C, N) buffer never materializes in the SCANNED HLO."""
    from repro.sharding.hlo import assert_flat_buffer_sharded
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = _scn(scenario)
    st, _ = _host_rounds(loss, copt, sopt, params, batches, scn, None,
                         flat="xla", mesh=mesh, federation=spec)

    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="xla",
                        mesh=mesh, federation=spec, scenario=scn,
                        num_clients=20)
    assert loop.state_form == "tree"
    with mesh:
        st2 = init_fl_state(
            jax.tree.map(lambda x: jnp.array(x, copy=True), params),
            sopt, scn)
        compiled = jax.jit(loop).lower(st2, batches).compile()
        st2, _ = compiled(st2, batches)
    _assert_states_equal(st.params, st2.params)
    assert_flat_buffer_sharded(compiled, C, loop.layout.padded_size)


@needs8
@pytest.mark.slow
def test_sharded_fused_compressed_hlo_boundary(rng):
    """Compressed sharded fused loop: bit-exact vs the compressed
    sharded host loop, and BOTH HLO assertions hold on the scanned
    computation — the (C, N) buffer stays sharded and no full-precision
    client delta crosses the client shard boundary inside the scan."""
    from repro.compression import CompressionSpec
    from repro.sharding.hlo import (assert_flat_buffer_sharded,
                                    assert_no_fullprec_delta_collective)
    from repro.sharding.spec import cross_device
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = cross_device(mesh)
    quad, params, batches = _problem(rng)
    loss = make_loss(quad)
    copt, sopt = get_client_opt("delta_sgd"), get_server_opt("fedavg")
    scn = _scn("bandwidth_tiered")
    comp = CompressionSpec(kind="int8", error_feedback=True)
    st, _ = _host_rounds(loss, copt, sopt, params, batches, scn, comp,
                         flat="xla", mesh=mesh, federation=spec)

    loop = make_fl_loop(loss, copt, sopt, params_like=params,
                        num_rounds=10, rounds_per_call=R, flat="xla",
                        mesh=mesh, federation=spec, scenario=scn,
                        num_clients=20, compression=comp)
    with mesh:
        st2 = init_fl_state(
            jax.tree.map(lambda x: jnp.array(x, copy=True), params),
            sopt, scn, compression=comp, cohort=C)
        compiled = jax.jit(loop).lower(st2, batches).compile()
        st2, _ = compiled(st2, batches)
    _assert_states_equal(st.params, st2.params)
    _assert_states_equal(st.ef, st2.ef)
    assert_flat_buffer_sharded(compiled, C, loop.layout.padded_size)
    assert_no_fullprec_delta_collective(compiled, C,
                                        loop.layout.padded_size,
                                        mesh=mesh, federation=spec)
